"""Continuous batching: admit requests mid-decode into freed cache slots.

The static serving path (``engine.greedy_generate``) decodes one fixed
batch to completion — every sequence occupies its cache row for the full
run even after it finishes.  Production traffic is a stream: requests
arrive at arbitrary times with mixed prompt/generation lengths.  This
scheduler keeps one batched decode loop hot over a fixed pool of ``slots``
cache rows and rotates requests through it:

  queued --admit--> prefill into a free slot --decode--> batched
  ``serve_step`` over all live slots --finish (EOS / max-new)--> slot
  freed --> head of the queue admitted into it, mid-decode.

Determinism / replayability
---------------------------
Admission is strictly FIFO over submission order, the freed-slot choice is
always the lowest free index, and analog decode keys derive from
``engine.decode_step_key`` over the scheduler's global step counter — the
same (params, requests, slots, seed) always produces the same event log.
Because batched decode rows are computed independently (pinned by
tests/test_serve_scheduler.py), every request's emitted tokens match a
per-request ``greedy_generate`` oracle token-for-token for digital params
and noise-free analog configs regardless of what else shares the batch;
noisy analog reads are replayable but draw batch-composition-dependent
noise, so they match the oracle in distribution only.

Sharding
--------
Pass a :class:`~repro.distributed.sharding.MeshPlan` to shard the slot
axis of the KV/SSD caches over the ``'data'`` replicas of the composed
``('pipe', 'data', 'array_row', 'array_col')`` mesh.  The plan is
validated against every tile grid an analog rule of the config could
route through — the same composition rules as training (data x
sharded-tile rejected; a grid the pool cannot hold composes fine through
the serial oracle).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.serve import engine

Array = jax.Array


@dataclasses.dataclass(frozen=True, eq=False)
class Request:
    """One generation request.  ``arrival`` is the scheduler tick at which
    the request becomes admissible (``run``'s synthetic-traffic clock).
    Identity semantics (``eq=False``): the ndarray prompt makes generated
    equality ambiguous, and two requests are never "the same" anyway."""
    rid: int
    prompt: np.ndarray                 # (P,) int32 token ids
    max_new_tokens: int
    arrival: int = 0


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]                  # all emitted tokens, EOS included
    reason: str                        # 'eos' | 'length'
    admitted_step: int
    finished_step: int
    slot: int


@dataclasses.dataclass(frozen=True)
class SlotEvent:
    """Replay-log entry; the property suite audits slot lifecycle on it."""
    kind: str                          # 'admit' | 'finish'
    step: int
    rid: int
    slot: int
    reason: str = ""


@dataclasses.dataclass
class _Active:
    rid: int
    last_token: int
    emitted: List[int]
    max_new_tokens: int
    admitted_step: int


def policy_tile_grids(cfg: ModelConfig) -> List[Tuple[int, int]]:
    """Distinct tile grids any analog rule of ``cfg`` could route through
    (mirrors the training driver's mesh-composition pre-check)."""
    grids = set()
    pol = getattr(cfg, "analog_policy", None)
    if pol is not None:
        for rule in pol.rules:
            if rule.cfg is not None and rule.cfg.tile_grid is not None:
                grids.add(rule.cfg.tile_grid)
    c = getattr(cfg, "analog", None)
    if c is not None and c.tile_grid is not None:
        grids.add(c.tile_grid)
    return sorted(grids)


def validate_serve_plan(cfg: ModelConfig,
                        plan: shd.MeshPlan,
                        n_devices: Optional[int] = None) -> shd.MeshPlan:
    """Validate a serve mesh plan, including composition with every tile
    grid the config's analog policy could place (``MeshPlan.validate``:
    data x sharded-tile rejected, unplaceable grids collapse to the serial
    oracle and compose fine)."""
    if n_devices is None:
        n_devices = jax.device_count()
    plan.validate(n_devices)
    for grid in policy_tile_grids(cfg):
        shd.MeshPlan(pipe=plan.pipe, data=plan.data,
                     tile=grid).validate(n_devices)
    return plan


class ContinuousBatchingScheduler:
    """Slot-rotating batched decode over a fixed cache pool.

    The two model-touching steps are isolated in :meth:`_admit_slot`
    (prefill one request, write its cache into a slot) and
    :meth:`_decode_tokens` (one batched ``serve_step`` + greedy argmax);
    everything else is pure slot/queue bookkeeping, which the property
    suite exercises against a stub engine by overriding exactly those two
    methods.
    """

    def __init__(self, params: Any, cfg: ModelConfig, *, slots: int,
                 max_seq: int, eos_id: Optional[int] = None,
                 akey: Optional[Array] = None,
                 plan: Optional[shd.MeshPlan] = None):
        self._init_bookkeeping(slots, eos_id)
        if cfg.encoder_layers > 0:
            raise NotImplementedError(
                "continuous batching does not thread encoder memories yet; "
                "enc-dec models serve through the static "
                "engine.greedy_generate path")
        self.params = params
        self.cfg = cfg
        self.max_seq = max_seq
        self.akey = akey

        self._mesh = None
        self._rules: Optional[shd.Rules] = None
        if plan is not None:
            validate_serve_plan(cfg, plan)
            if plan.n_placed(jax.device_count()) > 1:
                self._mesh = plan.build(jax.devices())
                self._rules = shd.ddp_rules()

        # The slot pool is built lazily from the first prefill's cache
        # pytree (zeros broadcast over the slot axis) rather than from
        # ``engine.init_cache``: the model decides cache leaf dtypes (e.g.
        # an f32 analog policy over a bf16 act config), and the pool must
        # match them exactly for slot insertion and the oracle comparison.
        self._cache: Optional[Dict[str, Array]] = None
        self._jit_prefill = jax.jit(self._prefill_impl)
        # the carried cache is donated: steady-state decode keeps one live
        # cache buffer, never two (pinned by the audit target's donation
        # program)
        self._jit_decode = jax.jit(self._decode_impl, donate_argnums=(2,))
        self._jit_insert = jax.jit(self._insert_impl, donate_argnums=(0,))

    def _init_bookkeeping(self, slots: int,
                          eos_id: Optional[int]) -> None:
        """Queue/slot state only — a stub-engine subclass (the property
        suite) calls this and overrides the two model-touching methods."""
        if slots < 1:
            raise ValueError(f"need at least one cache slot, got {slots}")
        self.slots = slots
        self.eos_id = eos_id
        self.queue: "deque[Request]" = deque()
        self.events: List[SlotEvent] = []
        self.completions: List[Completion] = []
        self._active: List[Optional[_Active]] = [None] * slots
        self._step = 0                 # global decode-step counter (keys)
        self._tick = 0                 # scheduler ticks (arrival clock)

    # --- model-touching internals (override points for the stub engine) --

    def _prefill_impl(self, params, prompt, akey):
        logits, cache = engine.prefill(params, prompt, self.cfg,
                                       max_seq=self.max_seq, akey=akey)
        return jnp.argmax(logits[:, -1], axis=-1), cache

    def _decode_impl(self, params, tokens_t, cache, akey):
        logits, cache = engine.serve_step(params, tokens_t, cache,
                                          self.cfg, akey=akey)
        return jnp.argmax(logits[:, -1], axis=-1), cache

    def _insert_impl(self, cache, cache1, slot):
        """Write a batch-1 prefill cache into slot ``slot`` of the pool.

        Every cache leaf carries batch on axis 1 under a leading layers
        axis, except the 1-D ``pos`` vector (batch on axis 0) — see
        ``engine.init_cache``.
        """
        def put(dst, src):
            if dst.ndim == 1:          # pos: (batch,)
                return jax.lax.dynamic_update_index_in_dim(
                    dst, src[0], slot, 0)
            return jax.lax.dynamic_update_index_in_dim(
                dst, src[:, 0], slot, 1)

        return {k: put(cache[k], cache1[k]) for k in cache}

    def _ensure_pool(self, cache1: Dict[str, Array]) -> None:
        """Materialise the slot pool from a batch-1 prefill cache tree."""
        if self._cache is not None:
            return

        def pooled(src):
            if src.ndim == 1:          # pos: (batch,)
                shape = (self.slots,)
            else:                      # (layers, batch, ...)
                shape = (src.shape[0], self.slots) + src.shape[2:]
            return jnp.zeros(shape, src.dtype)

        cache = jax.jit(lambda t: jax.tree_util.tree_map(pooled, t))(cache1)
        if self._mesh is not None:
            shardings = shd.tree_shardings(engine.cache_axes(self.cfg),
                                           self._mesh, self._rules,
                                           like=cache)
            cache = jax.device_put(cache, shardings)
        self._cache = cache

    def _ctx(self):
        if self._mesh is None:
            return _nullctx()
        return shd.use_sharding(self._mesh, self._rules)

    def _admit_slot(self, req: Request, slot: int) -> int:
        """Prefill ``req`` and park its cache in ``slot``; first token."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        with self._ctx():
            first, cache1 = self._jit_prefill(self.params, prompt, self.akey)
            self._ensure_pool(cache1)
            self._cache = self._jit_insert(self._cache, cache1,
                                           jnp.int32(slot))
        return int(first[0])

    def _decode_tokens(self, last_tokens: np.ndarray) -> np.ndarray:
        """One batched decode step; per-slot greedy next tokens (slots,)."""
        toks = jnp.asarray(last_tokens, jnp.int32)[:, None]
        step_key = engine.decode_step_key(self.akey, self._step)
        with self._ctx():
            nxt, self._cache = self._jit_decode(self.params, toks,
                                                self._cache, step_key)
        return np.asarray(nxt)

    # --- queue / slot bookkeeping ----------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def submit_many(self, reqs: Sequence[Request]) -> None:
        for r in reqs:
            self.submit(r)

    @property
    def idle(self) -> bool:
        return not self.queue and all(a is None for a in self._active)

    @property
    def n_free(self) -> int:
        return sum(a is None for a in self._active)

    def _finish(self, slot: int, reason: str) -> Completion:
        a = self._active[slot]
        assert a is not None
        comp = Completion(rid=a.rid, tokens=list(a.emitted), reason=reason,
                          admitted_step=a.admitted_step,
                          finished_step=self._tick, slot=slot)
        self.events.append(SlotEvent("finish", self._tick, a.rid, slot,
                                     reason))
        self.completions.append(comp)
        self._active[slot] = None
        return comp

    def _token_finishes(self, a: _Active, tok: int) -> Optional[str]:
        if self.eos_id is not None and tok == self.eos_id:
            return "eos"
        if len(a.emitted) >= a.max_new_tokens:
            return "length"
        return None

    def step(self) -> List[Completion]:
        """One scheduler tick: admissions, then one batched decode step.

        Returns the requests that finished during this tick (possibly at
        admission: a one-token request, or a first token that is EOS).
        """
        finished: List[Completion] = []

        # 1. admission: FIFO queue into lowest free slots; a request that
        # completes at its first (prefill) token frees its slot for the
        # next queued request within the same tick — no slot rides a tick
        # empty while work is queued.
        while self.queue and self.n_free > 0:
            req = self.queue.popleft()
            slot = next(i for i, a in enumerate(self._active) if a is None)
            first = self._admit_slot(req, slot)
            a = _Active(rid=req.rid, last_token=first, emitted=[first],
                        max_new_tokens=max(1, req.max_new_tokens),
                        admitted_step=self._tick)
            self._active[slot] = a
            self.events.append(SlotEvent("admit", self._tick, req.rid, slot))
            reason = self._token_finishes(a, first)
            if reason is not None:
                finished.append(self._finish(slot, reason))

        # 2. one batched decode step over the slot pool (free slots decode
        # garbage rows that are never read — row independence makes them
        # harmless, and the single fixed-shape dispatch is the point).
        if any(a is not None for a in self._active):
            last = np.asarray([a.last_token if a is not None else 0
                               for a in self._active], np.int32)
            nxt = self._decode_tokens(last)
            self._step += 1
            for slot, a in enumerate(self._active):
                if a is None:
                    continue
                tok = int(nxt[slot])
                a.last_token = tok
                a.emitted.append(tok)
                reason = self._token_finishes(a, tok)
                if reason is not None:
                    finished.append(self._finish(slot, reason))

        self._tick += 1
        return finished

    def run(self, requests: Sequence[Request],
            max_ticks: Optional[int] = None) -> List[Completion]:
        """Drive a whole synthetic-traffic trace to completion.

        Requests enter the admission queue at their ``arrival`` tick, in
        the order given (FIFO among same-tick arrivals) — the run is a
        pure function of (params, requests, slots, seed).
        """
        pending = deque(sorted(requests, key=lambda r: r.arrival))
        done: List[Completion] = []
        while pending or not self.idle:
            while pending and pending[0].arrival <= self._tick:
                self.submit(pending.popleft())
            done.extend(self.step())
            if max_ticks is not None and self._tick >= max_ticks:
                break
        return done


class _nullctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
