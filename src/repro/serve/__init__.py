"""Serving: prefill + batched decode (``engine``) and continuous batching
over a slot-allocated cache pool (``scheduler``).  Analog-converted params
serve through the same entry points — pass ``akey`` and every managed RPU
read runs in the per-token decode hot loop."""

from repro.serve import engine  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    Completion,
    ContinuousBatchingScheduler,
    Request,
    SlotEvent,
    validate_serve_plan,
)
