"""Serving engine: prefill + batched decode with per-layer caches.

``prefill``  lowers the ``prefill_32k`` cells: full flash forward over the
prompt while emitting every layer's decode cache (KV ring buffers for SWA,
SSD states for ssm/hybrid, static cross-attention memory for enc-dec).

``serve_step``  lowers the ``decode_32k`` / ``long_500k`` cells: one new
token per sequence against the cache — a scan over layers whose carried
activations are (B, 1, d), exactly the production batched-decode inner loop.

Caches are plain pytrees stacked over layers (leading L axis), so they shard
with the same logical rules as the parameters (kv_heads/model, batch/data).

Analog serving: params produced by ``convert_to_analog`` (AnalogState
tiles) dispatch through the same ``dense_apply`` type switch as training —
pass ``akey`` and every analog projection draws its managed read keys from
the same fold-in schedule as ``transformer.forward`` (per-layer ``li``,
unembed 203, adapter 202), so a policy-converted model decodes without any
engine-side special casing.  ``decode_step_key`` is THE per-token key
schedule: ``greedy_generate`` and the continuous-batching scheduler
(``serve/scheduler.py``) both derive each decode step's key through it,
which is what makes batched decode replayable and, for noise-free configs,
token-exact against per-request oracles.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models import transformer as T

Array = jax.Array

#: fold_in offset separating decode-step keys from the per-layer (li),
#: adapter (201/202), unembed (203) and encoder (1000+li) constants that
#: ``transformer``'s schedule consumes from the same base key.
DECODE_KEY_OFFSET = 1 << 20


def decode_step_key(akey, step):
    """Per-decode-step analog key: ``fold_in(akey, OFFSET + step)``.

    ``step`` may be a python int or a traced scalar (the ``greedy_generate``
    scan counter).  None passes through so digital callers stay key-free.
    """
    if akey is None:
        return None
    return jax.random.fold_in(akey, DECODE_KEY_OFFSET + step)


def cache_len_for(cfg: ModelConfig, max_seq: int) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.swa_window > 0:
        return min(cfg.swa_window, max_seq)
    return max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               src_len: int = 0) -> Dict[str, Array]:
    """Zero-initialised decode state (for dry-runs and fresh decode)."""
    c: Dict[str, Array] = {}
    hkv, hd, l = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    cl = cache_len_for(cfg, max_seq)
    kv_dt = jnp.int8 if cfg.kv_cache_quant else cfg.act_dtype
    if cfg.family != "ssm":
        c["k"] = jnp.zeros((l, batch, cl, hkv, hd), kv_dt)
        c["v"] = jnp.zeros((l, batch, cl, hkv, hd), kv_dt)
    if cfg.family in ("ssm", "hybrid"):
        from repro.models import ssm as S
        d_in, h, p_dim, n = S.dims(cfg)
        conv_ch = d_in + 2 * n
        c["ssm_conv"] = jnp.zeros((l, batch, cfg.ssm.d_conv - 1, conv_ch),
                                  cfg.act_dtype)
        c["ssm_state"] = jnp.zeros((l, batch, h, p_dim, n), jnp.float32)
    if cfg.encoder_layers > 0:
        c["cross_k"] = jnp.zeros((l, batch, src_len, hkv, hd), cfg.act_dtype)
        c["cross_v"] = jnp.zeros((l, batch, src_len, hkv, hd), cfg.act_dtype)
    c["pos"] = jnp.zeros((batch,), jnp.int32)
    return c


def cache_axes(cfg: ModelConfig) -> Dict[str, Any]:
    """Logical-axes tree matching :func:`init_cache` (for shardings)."""
    c: Dict[str, Any] = {}
    if cfg.family != "ssm":
        c["k"] = ("layers", "batch", "seq", "kv_heads", None)
        c["v"] = ("layers", "batch", "seq", "kv_heads", None)
    if cfg.family in ("ssm", "hybrid"):
        c["ssm_conv"] = ("layers", "batch", None, "mlp")
        c["ssm_state"] = ("layers", "batch", None, None, None)
    if cfg.encoder_layers > 0:
        c["cross_k"] = ("layers", "batch", "seq", "kv_heads", None)
        c["cross_v"] = ("layers", "batch", "seq", "kv_heads", None)
    c["pos"] = ("batch",)
    return c


def prefill(params, tokens: Array, cfg: ModelConfig, *, max_seq: int,
            enc_embeds: Optional[Array] = None,
            akey=None) -> Tuple[Array, Dict[str, Array]]:
    """Process the prompt; returns (last-position logits, decode cache)."""
    x = L.embed_apply(params["embed"], tokens)
    enc_out = None
    if cfg.encoder_layers > 0:
        e = enc_embeds.astype(x.dtype)
        if "adapter" in params:        # frontend adapter (as in forward())
            ek = None if akey is None else jax.random.fold_in(akey, 202)
            e = L.dense_apply(params["adapter"], e, key=ek)
        e_pos = jnp.arange(e.shape[1])[None]
        e, _ = T._scan_layers_enc(params["enc_layers"], e, cfg,
                                  positions=e_pos, akey=akey)
        enc_out = L.rmsnorm_apply(params["enc_norm"], e, cfg.norm_eps)

    positions = jnp.arange(x.shape[1])[None]
    cl = cache_len_for(cfg, max_seq)

    def body(carry, inp):
        xx = carry
        layer_p, li = inp
        lk = None if akey is None else jax.random.fold_in(akey, li)
        yy, _, cache = T.block_prefill(layer_p, xx, cfg,
                                       positions=positions, cache_len=cl,
                                       enc_out=enc_out, akey=lk)
        return yy, cache

    if cfg.remat:
        body = jax.checkpoint(body)
    n = cfg.n_layers
    x, caches = jax.lax.scan(
        body, x, (params["layers"], jnp.arange(n)))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    x_last = x[:, -1:]
    if cfg.tie_embeddings:
        logits = L.unembed_apply(params["embed"], x_last)
    else:
        uk = None if akey is None else jax.random.fold_in(akey, 203)
        logits = L.dense_apply(params["unembed"], x_last, key=uk)
    caches["pos"] = jnp.full((tokens.shape[0],), tokens.shape[1],
                             jnp.int32)
    return logits, caches


def serve_step(params, tokens_t: Array, cache: Dict[str, Array],
               cfg: ModelConfig, akey=None
               ) -> Tuple[Array, Dict[str, Array]]:
    """One batched decode step.  tokens_t (B, 1) -> (logits (B,1,V), cache)."""
    pos = cache["pos"]
    x = L.embed_apply(params["embed"], tokens_t)
    x = shard(x, "batch", None, "embed_act")

    layer_cache = {k: v for k, v in cache.items() if k != "pos"}

    def body(x_t, inp):
        layer_p, lc, li = inp
        lk = None if akey is None else jax.random.fold_in(akey, li)
        y_t, nc = T.block_decode(layer_p, x_t, lc, pos, cfg, akey=lk)
        return y_t, nc

    n = cfg.n_layers
    x, new_layer_cache = jax.lax.scan(
        body, x, (params["layers"], layer_cache, jnp.arange(n)))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed_apply(params["embed"], x)
    else:
        uk = None if akey is None else jax.random.fold_in(akey, 203)
        logits = L.dense_apply(params["unembed"], x, key=uk)
    new_cache = dict(new_layer_cache)
    new_cache["pos"] = pos + 1
    return logits, new_cache


def greedy_generate(params, prompt: Array, cfg: ModelConfig, *,
                    n_steps: int, max_seq: int,
                    enc_embeds: Optional[Array] = None, akey=None):
    """Simple batched greedy loop (example/e2e-test driver).

    With ``akey`` the prefill consumes the base key and decode step ``i``
    consumes ``decode_step_key(akey, i)``.  The continuous-batching
    scheduler derives its keys through the same function (over its global
    step counter), so scheduler runs are replayable; a per-request run of
    this loop is the scheduler's token-parity oracle — exact for digital
    params and for noise-free analog configs (whose reads are
    key-independent), fresh-noise-per-step for noisy configs.
    """
    logits, cache = prefill(params, prompt, cfg, max_seq=max_seq,
                            enc_embeds=enc_embeds, akey=akey)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]

    def step(carry, i):
        tok, cache = carry
        logits, cache = serve_step(params, tok, cache, cfg,
                                   akey=decode_step_key(akey, i))
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return (nxt, cache), nxt.squeeze(-1)

    (_, cache), toks = jax.lax.scan(step, (tok, cache),
                                    jnp.arange(n_steps - 1))
    out = jnp.concatenate([tok, toks.T], axis=1)
    return out, cache
