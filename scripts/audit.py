#!/usr/bin/env python
"""Static-analysis audit gate: trace named targets, diff against budgets.

Traces the registered audit targets (``repro.analysis.targets``) on CPU,
projects the stable invariants (launch counts, collective rounds, donation
outcomes, hygiene counters) and diffs them against the checked-in budgets
in ``analysis/budgets/``.  Any movement — regression OR improvement —
exits 1; land intentional changes by refreshing the budget with
``--update`` in the same PR so the contract diff shows up in review.

Run:  PYTHONPATH=src python scripts/audit.py                    # gate all
      PYTHONPATH=src python scripts/audit.py lenet              # one target
      PYTHONPATH=src python scripts/audit.py --update           # refresh
      PYTHONPATH=src python scripts/audit.py --report out.json  # artifact

``--force-devices N`` (default 8) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` BEFORE importing
jax so the sharded tile-grid target can place its crossbar mesh on a CPU
host; pass 0 to leave the environment alone.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def parse_args(argv):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("targets", nargs="*",
                    help="target names (default: all registered)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the budget files from this trace")
    ap.add_argument("--budget-dir", default=None,
                    help="budget directory (default: <repo>/analysis/budgets)")
    ap.add_argument("--report", default=None,
                    help="write the full (unprojected) reports + diffs here")
    ap.add_argument("--force-devices", type=int, default=8, metavar="N",
                    help="force N host devices via XLA_FLAGS before "
                         "importing jax (0 = leave environment alone)")
    ap.add_argument("--list", action="store_true",
                    help="list registered targets and exit")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv if argv is not None else sys.argv[1:])

    if args.force_devices > 0:
        flag = (f"--xla_force_host_platform_device_count="
                f"{args.force_devices}")
        prev = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in prev:
            os.environ["XLA_FLAGS"] = f"{prev} {flag}".strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    # import AFTER the environment is pinned: jax reads XLA_FLAGS at init
    from repro.analysis import budgets
    from repro.analysis.targets import TARGETS

    if args.list:
        for name in sorted(TARGETS):
            print(name)
        return 0

    names = args.targets or sorted(TARGETS)
    unknown = [n for n in names if n not in TARGETS]
    if unknown:
        print(f"unknown target(s): {', '.join(unknown)}; "
              f"registered: {', '.join(sorted(TARGETS))}", file=sys.stderr)
        return 2

    bdir = args.budget_dir
    artifact = {}
    failed = False
    for name in names:
        if args.update:
            out = TARGETS[name]()
            path = budgets.save_budget(name, out, bdir)
            print(f"[audit] {name}: budget written -> {path}")
            artifact[name] = {"reports": out, "diffs": [],
                              "budget": str(path)}
            continue
        out, diffs = budgets.check_target(name, bdir)
        artifact[name] = {"reports": out, "diffs": diffs}
        if diffs:
            failed = True
            print(f"[audit] {name}: BUDGET VIOLATION "
                  f"({len(diffs)} mismatch(es))")
            for d in diffs:
                print(f"  {d}")
        else:
            progs = ", ".join(sorted(out))
            print(f"[audit] {name}: ok ({progs})")

    if args.report:
        with open(args.report, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"[audit] report -> {args.report}")

    if failed:
        print("[audit] FAILED: invariants moved; if intentional, refresh "
              "with scripts/audit.py --update and commit the budget diff",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
