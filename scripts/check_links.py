#!/usr/bin/env python
"""Docs link check: dead relative links AND stale ``file:line`` code refs.

Two classes of rot, both CI-gated:

* markdown links/images ``[text](target)`` whose relative target no longer
  exists on disk (external ``http(s)://``/``mailto:`` and pure-anchor
  links are skipped);
* backticked code references `` `path/to/file.py:123` `` — the convention
  the docs use to point at specific lines — whose file is gone or is now
  shorter than the referenced line.  Plain backticked paths without a line
  number are checked for existence only when they look like repo paths
  (contain a ``/`` and a known suffix).

Relative links resolve against the markdown file's directory; code refs
resolve against the repo root (that is how they are written in the docs).
Fenced code blocks are dropped before scanning — command examples are not
references.

Run:  python scripts/check_links.py [files/dirs ...]
      (default: README.md docs CHANGES.md ROADMAP.md)
"""

from __future__ import annotations

import os
import re
import sys

# inline markdown links, excluding images' alt brackets handled the same way
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = ("http://", "https://", "mailto:", "#")

# `path/file.py:123` (line ref) and bare `path/file.py` repo-path mentions
_CODE_REF = re.compile(r"`([\w][\w./\-]*\.(?:py|md|toml|yml|yaml|json))"
                       r"(?::(\d+))?`")
# run-time artifact dirs the docs legitimately name before they exist
_GENERATED = ("results/",)
_DEFAULT_ROOTS = ["README.md", "docs", "CHANGES.md", "ROADMAP.md"]


def repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def md_files(paths):
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                for n in sorted(names):
                    if n.endswith(".md"):
                        yield os.path.join(root, n)
        elif p.endswith(".md"):
            yield p


def _strip_fences(text: str) -> str:
    # drop fenced code blocks: command examples are not links
    return re.sub(r"```.*?```", "", text, flags=re.S)


def dead_links(md_path: str):
    base = os.path.dirname(os.path.abspath(md_path))
    with open(md_path, encoding="utf-8") as f:
        text = _strip_fences(f.read())
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, path))):
            yield f"dead link ({target})"


def _resolve_ref(path: str, root: str):
    """First existing candidate for a doc code ref.

    The docs write refs either repo-root-relative (``scripts/audit.py``,
    ``docs/scaling.md``) or package-relative (``core/tile.py`` meaning
    ``src/repro/core/tile.py``) — accept both spellings."""
    for base in (root, os.path.join(root, "src"),
                 os.path.join(root, "src", "repro")):
        full = os.path.normpath(os.path.join(base, path))
        if os.path.exists(full):
            return full
    return None


def stale_code_refs(md_path: str, root: str):
    """Backticked repo-path refs whose file or line no longer exists."""
    with open(md_path, encoding="utf-8") as f:
        text = _strip_fences(f.read())
    for m in _CODE_REF.finditer(text):
        path, line = m.group(1), m.group(2)
        if "/" not in path:
            continue                    # `engine.py`-style mention, not a ref
        if path.startswith(_GENERATED):
            continue                    # benchmark/run output, written later
        full = _resolve_ref(path, root)
        if full is None:
            yield f"stale code ref `{m.group(0)[1:-1]}`: no such file"
            continue
        if line is not None:
            with open(full, encoding="utf-8", errors="replace") as f:
                n_lines = sum(1 for _ in f)
            if int(line) > n_lines:
                yield (f"stale code ref `{m.group(0)[1:-1]}`: file has "
                       f"only {n_lines} lines")


def main(argv):
    roots = argv[1:] or [os.path.join(repo_root(), p)
                         for p in _DEFAULT_ROOTS]
    root = repo_root()
    bad = []
    n_files = 0
    for md in md_files(roots):
        n_files += 1
        bad.extend((md, p) for p in dead_links(md))
        bad.extend((md, p) for p in stale_code_refs(md, root))
    if bad:
        for md, problem in bad:
            print(f"DEAD LINK {md}: {problem}")
        print(f"[check_links] {len(bad)} dead link(s)/stale ref(s) "
              f"in {n_files} file(s)")
        return 1
    print(f"[check_links] OK — {n_files} markdown file(s), "
          "no dead links or stale code refs")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
