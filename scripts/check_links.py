#!/usr/bin/env python
"""Docs link check: fail on dead *relative* links in README and docs/.

Scans markdown files for inline links/images ``[text](target)`` and
verifies that every relative target (optionally with a ``#fragment``)
exists on disk.  External (``http(s)://``, ``mailto:``) and pure-anchor
links are skipped.  Exit code 1 lists every dead link — wired into CI so
renames/moves cannot silently strand the documentation.

Run:  python scripts/check_links.py [files/dirs ...]   (default: README.md docs)
"""

from __future__ import annotations

import os
import re
import sys

# inline markdown links, excluding images' alt brackets handled the same way
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = ("http://", "https://", "mailto:", "#")


def md_files(paths):
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                for n in sorted(names):
                    if n.endswith(".md"):
                        yield os.path.join(root, n)
        elif p.endswith(".md"):
            yield p


def dead_links(md_path: str):
    base = os.path.dirname(os.path.abspath(md_path))
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    # drop fenced code blocks: command examples are not links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, path))):
            yield target


def main(argv):
    roots = argv[1:] or ["README.md", "docs"]
    bad = []
    n_files = 0
    for md in md_files(roots):
        n_files += 1
        bad.extend((md, t) for t in dead_links(md))
    if bad:
        for md, target in bad:
            print(f"DEAD LINK {md}: ({target})")
        print(f"[check_links] {len(bad)} dead relative link(s) "
              f"in {n_files} file(s)")
        return 1
    print(f"[check_links] OK — {n_files} markdown file(s), "
          "no dead relative links")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
