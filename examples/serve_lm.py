"""Serve a small LM with batched requests: prefill + greedy decode.

Uses the serving engine (KV caches / SSM states / SWA ring buffers) on the
reduced configs; on a TPU pod the same engine serves the full configs via
``repro.launch.serve``.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch mixtral_8x7b]
"""

import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral_8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    out = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen=args.gen, smoke=True)
    print("generated token ids (first request):", out[0][:16], "...")


if __name__ == "__main__":
    main()
