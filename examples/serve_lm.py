"""Serve a small LM: static batched decode or continuous batching.

Uses the serving engine (KV caches / SSM states / SWA ring buffers) on the
reduced configs; on a TPU pod the same engine serves the full configs via
``repro.launch.serve``.  With ``--analog-policy`` the params are converted
to RPU crossbar tiles and every projection in the decode loop is a managed
analog read; with ``--continuous`` requests rotate through cache slots
mid-decode instead of padding one static batch.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch mixtral_8x7b]
      PYTHONPATH=src python examples/serve_lm.py --arch deepseek_7b \
          --analog-policy noise_free --continuous
"""

import argparse

from repro.launch.serve import serve, serve_continuous


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral_8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--analog-policy", default=None,
                    help="serve on analog tiles, e.g. 'noise_free' "
                         "(bit-exact vs digital) or 'lm_managed'")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over a slot pool instead of "
                         "one static batch")
    args = ap.parse_args()
    if args.continuous:
        done = serve_continuous(args.arch, slots=args.batch,
                                n_requests=args.batch * 3,
                                prompt_len=args.prompt_len, gen=args.gen,
                                smoke=True,
                                analog_policy=args.analog_policy)
        print("first completion tokens:", done[0].tokens)
    else:
        out = serve(args.arch, batch=args.batch,
                    prompt_len=args.prompt_len, gen=args.gen, smoke=True,
                    analog_policy=args.analog_policy)
        print("generated token ids (first request):", out[0][:16], "...")


if __name__ == "__main__":
    main()
