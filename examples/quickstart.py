"""Quickstart: the RPU analog substrate in five minutes.

Demonstrates the paper's core objects directly:
  1. an analog crossbar tile (Table-1 device physics),
  2. what goes wrong without management (noise drowns small signals,
     bounds clip large ones),
  3. noise management (Eq. 3) and bound management (Eq. 4) fixing it,
  4. a stochastic pulse-update cycle (Eq. 1) moving the weights,
  5. the unified analog API: per-layer policies + ``convert_to_analog``
     turning any digital network's dense layers into analog tiles.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.analog import (AnalogState, convert_to_analog, conversion_plan,
                          parse_policy, to_digital)
from repro.core import (RPUConfig, analog_mvm_reference, init_tile,
                        tile_backward, tile_forward, tile_update)
from repro.core import management


def main():
    key = jax.random.key(0)
    cfg = RPUConfig()                        # Table-1 RPU baseline
    tile = init_tile(key, out_features=8, in_features=16, cfg=cfg)
    w_eff = tile.w
    print(f"tile: {tile.w.shape} crossbar, |w| bounds ~{cfg.w_bound}")

    # --- 1) noise: a small backward-cycle error vector ----------------------
    delta = 1e-3 * jax.random.normal(jax.random.key(1), (1, 8))
    clean = delta @ w_eff
    z_raw, _ = analog_mvm_reference(tile.w, delta, jax.random.key(2), cfg,
                                    transpose=True)
    z_nm, _ = management.with_management(
        lambda x, k: analog_mvm_reference(tile.w, x, k, cfg, transpose=True),
        delta, jax.random.key(2),
        cfg.with_management(nm=True, bm=False), backward=True)
    print("\nbackward read of a 1e-3-scale error vector:")
    print(f"  true |z|      = {float(jnp.abs(clean).mean()):.2e}")
    print(f"  raw analog    = {float(jnp.abs(z_raw - clean).mean()):.2e} "
          f"error  (noise sigma={cfg.read_noise} dominates!)")
    print(f"  with NM       = "
          f"{float(jnp.abs(z_nm - clean).mean()):.2e} error")

    # --- 2) bounds: a large forward signal ----------------------------------
    big_x = 30.0 * jnp.ones((1, 16))
    y_raw, sat = analog_mvm_reference(tile.w, big_x, jax.random.key(3), cfg)
    y_bm, _ = management.with_management(
        lambda x, k: analog_mvm_reference(tile.w, x, k, cfg),
        big_x, jax.random.key(3),
        cfg.with_management(nm=False, bm=True), backward=False)
    true_y = big_x @ w_eff.T
    print(f"\nforward read with outputs beyond the bound alpha="
          f"{cfg.out_bound}: saturated={bool(sat[0])}")
    print(f"  raw analog error  = "
          f"{float(jnp.abs(y_raw - true_y).max()):.2f}")
    print(f"  with BM error     = "
          f"{float(jnp.abs(y_bm - true_y).max()):.2f}")

    # --- 3) one stochastic pulse-update cycle -------------------------------
    x = jax.random.normal(jax.random.key(4), (4, 16)) * 0.5
    d = jax.random.normal(jax.random.key(5), (4, 8)) * 0.2
    new_tile = tile_update(tile, x, d, jax.random.key(6), cfg, lr=0.01)
    dw = new_tile.w - tile.w
    expect = 0.01 * d.T @ x
    print(f"\npulse update: E[dW]=lr*d^T x; measured corr = "
          f"{float(jnp.corrcoef(dw.ravel(), expect.ravel())[0, 1]):.2f} "
          f"(stochastic, BL={cfg.bl})")

    # --- 4) per-layer policies: any digital net -> analog tiles -------------
    # Ordered pattern rules, first match wins; unmatched layers stay
    # digital.  This is the paper's selective-layer technique (13-device
    # mapping on K2 only, Fig. 4) generalised to every architecture.
    k = jax.random.key(7)
    mlp = {"hidden": {"w": 0.1 * jax.random.normal(k, (16, 32))},
           "head": {"w": 0.1 * jax.random.normal(k, (32, 10))}}
    axes = {"hidden": {"w": ("embed", "mlp")}, "head": {"w": ("mlp", "vocab")}}
    policy = parse_policy("hidden=managed,head=digital")
    aparams, _ = convert_to_analog(mlp, axes, policy, key=k,
                                   normalize=RPUConfig.normalized_for_lm)
    print("\nper-layer policy ('hidden=managed,head=digital'):")
    for path, label, _cfg in conversion_plan(aparams):
        kind = (type(aparams.get(path, None)).__name__
                if not isinstance(aparams.get(path), dict) else "dict (fp)")
        print(f"  {path:<8} -> {label:<8} ({kind})")
    assert isinstance(aparams["hidden"], AnalogState)
    back = to_digital(aparams)   # effective weights, bit-exact round trip
    assert bool(jnp.all(back["hidden"]["w"] == mlp["hidden"]["w"]))
    print("convert_to_analog -> to_digital round trip: bit-exact")

    print("\nSee examples/train_lenet_analog.py for the full paper "
          "reproduction (policy-driven per-layer configs), "
          "`python -m repro.launch.train --analog-policy ...` for LM "
          "training, and examples/serve_lm.py for LM serving.")


if __name__ == "__main__":
    main()
