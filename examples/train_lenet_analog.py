"""End-to-end paper reproduction driver: train the paper's CNN on analog
RPU arrays with all management techniques, next to the FP baseline.

Default: compressed protocol (a few minutes on CPU).  ``--paper`` uses the
full 30-epoch protocol (needs real MNIST under data/mnist and hours).

Run:  PYTHONPATH=src python examples/train_lenet_analog.py [--quick]
"""

import argparse

from repro.core import device as dev
from repro.models.lenet import LeNetConfig
from repro.train import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 epochs / 2k images (sanity-scale)")
    ap.add_argument("--paper", action="store_true",
                    help="full 30-epoch 60k-image protocol")
    args = ap.parse_args()
    if args.paper:
        proto = dict(epochs=30, batch=1, n_train=60000, n_test=10000)
    elif args.quick:
        proto = dict(epochs=2, batch=8, n_train=2048, n_test=1024)
    else:
        proto = dict(epochs=8, batch=8, n_train=4096, n_test=2048)

    print("=== FP baseline (digital) ===")
    fp = cnn.train(LeNetConfig.uniform(dev.rpu_baseline(), mode="digital"),
                   **proto)

    print("\n=== full RPU model: NM + BM + UM(BL=1) + 13-device K2 ===")
    full_cfg = LeNetConfig.uniform(dev.rpu_nm_bm_um_bl1()).replace_layer(
        "K2", dev.rpu_full(13))
    rpu = cnn.train(full_cfg, **proto)

    print(f"\nFP baseline final error : {100 * fp['final_error']:.2f}%")
    print(f"full RPU model error    : {100 * rpu['final_error']:.2f}%")
    print("paper: 0.8% vs 0.8% (indistinguishable); see EXPERIMENTS.md")


if __name__ == "__main__":
    main()
