"""End-to-end paper reproduction driver: train the paper's CNN on analog
RPU arrays with all management techniques, next to the FP baseline.

Per-layer device configs are expressed through the unified analog API
(``repro.analog``): one ordered rule list gives K2 the paper's 13-device
mapping while every other tile runs the managed (NM+BM+UM, BL=1) model —
the Fig. 6 recipe as a policy spec::

    K2=k2_multi_device,*=managed

Default: compressed protocol (a few minutes on CPU).  ``--paper`` uses the
full 30-epoch protocol (needs real MNIST under data/mnist and hours);
``--smoke`` is the CI-sized API-surface check.

Run:  PYTHONPATH=src python examples/train_lenet_analog.py [--quick]
"""

import argparse

from repro.analog import parse_policy
from repro.models.lenet import LeNetConfig
from repro.train import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1 epoch / 256 images (CI API-surface check)")
    ap.add_argument("--quick", action="store_true",
                    help="2 epochs / 2k images (sanity-scale)")
    ap.add_argument("--paper", action="store_true",
                    help="full 30-epoch 60k-image protocol")
    ap.add_argument("--policy", type=str,
                    default="K2=k2_multi_device,*=managed",
                    help="per-layer analog policy rules over K1/K2/W3/W4 "
                         "(see repro.analog.presets)")
    args = ap.parse_args()
    if args.paper:
        proto = dict(epochs=30, batch=1, n_train=60000, n_test=10000)
    elif args.quick:
        proto = dict(epochs=2, batch=8, n_train=2048, n_test=1024)
    elif args.smoke:
        proto = dict(epochs=1, batch=8, n_train=256, n_test=128)
    else:
        proto = dict(epochs=8, batch=8, n_train=4096, n_test=2048)

    policy = parse_policy(args.policy)
    print("=== FP baseline (digital) ===")
    fp = cnn.train(LeNetConfig.from_policy(policy, mode="digital"), **proto)

    print(f"\n=== RPU model, per-layer policy: {args.policy} ===")
    rpu = cnn.train(LeNetConfig.from_policy(policy), **proto)

    print(f"\nFP baseline final error : {100 * fp['final_error']:.2f}%")
    print(f"full RPU model error    : {100 * rpu['final_error']:.2f}%")
    print("paper: 0.8% vs 0.8% (indistinguishable); see EXPERIMENTS.md")


if __name__ == "__main__":
    main()
