"""End-to-end LM training driver: mamba2-130m (the ~100M-parameter assigned
arch) for a few hundred steps with checkpointing + fault tolerance.

Default runs a scaled-down config so the example finishes in minutes on CPU;
``--full`` trains the real 130M configuration (use a TPU host).

Run:  PYTHONPATH=src python examples/train_lm_100m.py [--full] [--analog]
"""

import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="real mamba2-130m config (slow on CPU)")
    ap.add_argument("--analog", action="store_true",
                    help="train on analog RPU tiles (the paper's technique "
                         "applied to an LM)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    res = train("mamba2_130m", steps=args.steps, batch=4,
                seq=256 if args.full else 128, smoke=not args.full,
                analog=args.analog, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                log_every=10)
    losses = res["losses"]
    if losses:
        k = max(len(losses) // 10, 1)
        print(f"\nloss: first-{k}-mean {sum(losses[:k]) / k:.3f} -> "
              f"last-{k}-mean {sum(losses[-k:]) / k:.3f}")


if __name__ == "__main__":
    main()
